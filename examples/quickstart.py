"""Quickstart: the paper's algorithms end-to-end on its own examples.

    PYTHONPATH=src python examples/quickstart.py

Walks Alg.1 → Alg.3 (fission), Alg.4 → Alg.5 (send/wait insertion), and the
Alg.6/Fig.6 synchronization elimination, executing everything on real
threads and validating against sequential semantics.

The compiler entry point is the *staged* pipeline::

    options = PlanOptions(method="isd")          # typed, validated knobs
    p       = plan(prog, options)                # analysis runs ONCE
    exe     = p.compile("wavefront")             # schedule for one machine
    store   = exe.run()                          # uniform run contract
    report  = exe.report()                       # the familiar report

Migration from the legacy one-shot call:

    ===================================================  =========================================================
    before                                               after
    ===================================================  =========================================================
    parallelize(prog, method=m)                          plan(prog, method=m).compile("threaded").report()
    parallelize(prog, method=m, backend=b)               plan(prog, method=m).compile(b).report()
    parallelize(prog, ..., scc_policy=s, chunk_limit=c)  plan(prog, ...).compile(b, scc_policy=s, chunk_limit=c)
    rep.wavefront / rep.compiled                         exe.report().wavefront / exe.artifacts["compiled"]
    ===================================================  =========================================================

``parallelize()`` survives as a shim with bit-identical reports, but warns:
it re-runs the whole analysis per call, where a plan is computed once and
compiled for any number of backends — each applying its own capability
contract and cost model (step 3b below shows wavefront and xla choosing
different schedules for one plan).

Serving many structures concurrently is the job of the **plan service**
(:mod:`repro.serve`, step 4b below)::

    svc = PlanService(ServiceOptions(workers=4, plan_cache_capacity=8))
    fut = svc.submit(prog, PlanOptions(method="isd"), tenant="decode",
                     run=True)
    res = fut.result()        # ServiceResult: plan, executable, store
    svc.drain(); svc.close()  # or: with PlanService(...) as svc

``ServiceOptions`` rejects unknown knobs at construction with a ValueError
naming the accepted set, like ``PlanOptions`` and the backend capability
contracts.  Migration from the helpers that used to live inside the
``repro.launch.serve`` demo client (unbounded ``functools.lru_cache``
memos, now bounded per-tenant LRUs on the process-default service):

    ==========================================  ==========================================================
    before (repro.launch.serve internals)       after (repro.serve, the public surface)
    ==========================================  ==========================================================
    launch.serve.plan_wave_sync(m) (lru_cache)  repro.serve.plan_wave_sync(m)   — tenant "decode"
    launch.serve.plan_scan_sync(s, h)           repro.serve.plan_scan_sync(s, h) — tenant "scan"
    launch.serve.plan_route_sync(t)             repro.serve.plan_route_sync(t)  — tenant "route"
    launch.serve.plan_rescore_sync(t)           repro.serve.plan_rescore_sync(t) — tenant "rescore"
    launch.serve.plan_wave(m, s, pool)          repro.serve.plan_wave(m, s, pool)
    <helper>.cache_clear()                      obs.reset_all()  (resets the default service too)
    ad-hoc plan()+compile() per request         PlanService.submit(prog, options, tenant=..., run=True)
    ==========================================  ==========================================================

(The ``launch.serve`` names still import — they are re-exports of the
``repro.serve`` surface now.)
"""

from repro.core import (
    ArrayRef,
    LoopProgram,
    PlanOptions,
    StageGraph,
    Statement,
    analyze,
    fission,
    indexed_store,
    inspect_dependences,
    paper_alg1,
    paper_alg4,
    paper_alg6,
    plan,
    plan_pipeline_sync,
    run_sequential,
    run_threaded,
    sparse_matvec,
)
from repro.core.dependence import paper_alg4_dependences
from repro.core.sync import insert_synchronization


def main() -> None:
    print("=" * 70)
    print("1. Alg.1 -> Alg.2/3: dependence analysis + loop fission (Fig. 3)")
    print("=" * 70)
    prog = paper_alg1()
    for d in analyze(prog):
        print("  dep:", d.pretty())
    res = fission(prog)
    print("  fissioned loops:", res.loop_names(), "(paper: [S2],[S1,S4],[S3])")

    print()
    print("=" * 70)
    print("2. Alg.4 -> Alg.5: send/wait synchronization (Fig. 5)")
    print("=" * 70)
    prog4 = paper_alg4()
    sync = insert_synchronization(prog4, paper_alg4_dependences())
    print(sync.pretty())
    print()
    print("  NOTE: our analyzer additionally finds", end=" ")
    extra = [
        d for d in analyze(prog4)
        if (d.source, d.sink, d.array) == ("S2", "S1", "b")
    ]
    print(extra[0].pretty(), "- missing from the paper's Alg.5 (race demo in tests).")

    print()
    print("=" * 70)
    print("3. Alg.6: synchronization elimination (Fig. 6), staged pipeline")
    print("=" * 70)
    p = plan(paper_alg6(8), PlanOptions(method="isd"))  # analysis runs ONCE
    rep = p.compile("threaded").report()
    print("  summary:", rep.summary())
    for dep, path in rep.elimination.witnesses.items():
        chain = " -> ".join(f"{s}({i[0]})" for s, i in path)
        print(f"  eliminated {dep.pretty()}")
        print(f"  witness:   {chain}")
    run = run_threaded(rep.optimized_sync, stalls={("S3", (1,)): 0.05})
    print(
        f"  threaded execution matches sequential: {run.matches_sequential} "
        f"(waits={run.stats.waits}, sends={run.stats.sends})"
    )
    # the SAME plan compiles for the fast NumPy backend — no re-analysis
    wf = p.compile("wavefront").report().wavefront
    print(
        f"  wavefront compile of the same plan: depth={wf.depth} "
        f"(batched_ops={wf.batched_ops})"
    )

    print()
    print("=" * 70)
    print("3b. One plan, per-backend schedules (capability cost hooks)")
    print("=" * 70)
    # {(0,1), (1,-1)} recurrence: the (0,1) carried dep pins DOACROSS
    # chunks to 1, so the NumPy interpreter (cost = depth x groups) skews;
    # the compiled level loop pays per padded lane width and chunks instead.
    rec = LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, 5), (0, 16)),
    )
    p2 = plan(rec, PlanOptions(method="isd"))
    for backend in ("wavefront", "xla"):
        (r,) = p2.compile(backend).report().summary()["scc"]["recurrences"]
        print(f"  {backend:<10s} strategy={r['strategy']}")

    print()
    print("=" * 70)
    print('3c. Non-affine loops: deps="inspect" (runtime inspector stage)')
    print("=" * 70)
    # y[row[k]] += v[k] * x[col[k]]: the static analyzer can only emit the
    # serializing Δ=1 proxy chain; the inspector evaluates row[] at
    # plan-per-bounds time and schedules the exact instance graph instead.
    spmv = sparse_matvec(12)
    store = indexed_store(
        spmv, {"row": [0, 1, 2, 0, 3, 1, 4, 5, 2, 6, 7, 0],
               "col": list(range(12))}
    )
    from repro.core import affine_retained
    from repro.core.wavefront import schedule_levels

    insp = inspect_dependences(spmv, store)
    conservative = plan(spmv).compile("wavefront")
    inspected_plan = plan(spmv, PlanOptions(deps="inspect"))
    exact = schedule_levels(
        spmv,
        list(affine_retained(inspected_plan.retained)),
        instance_edges=insp.edges,
    )
    out = inspected_plan.compile("wavefront").run(
        store={a: dict(c) for a, c in store.items()}
    )
    print(f"  inspector: {insp.summary()}")
    print(
        f"  conservative depth={conservative.artifacts['wavefront'].depth} "
        f"(proxy chain serializes all 12 iterations)"
    )
    print(
        f"  deps='inspect' depth={exact.depth} (row 0 hit three times; "
        "distinct rows run doall)"
    )
    print(
        "  bit-equal to sequential oracle:",
        out == run_sequential(spmv, store),
    )

    print()
    print("=" * 70)
    print("4. Observability: span tracing + the unified metrics registry")
    print("=" * 70)
    # Tracing is off by default (the hot paths pay one hoisted branch);
    # inside the context manager every pipeline phase records a span.
    import json

    from repro import obs
    from repro.obs import metrics, trace

    obs.reset_all()
    with trace.tracing():
        exe = plan(paper_alg6(16), PlanOptions(method="isd")).compile(
            "wavefront"
        )
        exe.run()
    doc = json.loads(exe.trace_json())  # Chrome-trace: chrome://tracing
    phases = sorted({e["name"] for e in doc["traceEvents"]})
    print(f"  traced {len(doc['traceEvents'])} spans: {', '.join(phases)}")
    snap = metrics.snapshot()
    print(
        "  metrics: analysis misses={}, backend.runs.wavefront={}".format(
            snap["analysis_cache.misses"], snap["backend.runs.wavefront"]
        )
    )
    # predicted-vs-measured per strategy offer: the cost-model auction's
    # full scoreboard rides every recurrence row; the profiler pairs the
    # winner's predicted cost with a measured wall time (SYNC_REPORTS
    # carries these rows per benchmark program).
    from repro.obs import profile

    rec2 = plan(rec, PlanOptions(method="isd")).compile("wavefront")
    (row,) = profile.profile_executable(rec2, program="quickstart_rec")
    print(
        f"  profiler: strategy={row['strategy']} "
        f"predicted={row['predicted']} measured_us={row['measured_us']:.0f}"
    )
    obs.reset_all()

    print()
    print("=" * 70)
    print("4b. Serving: the multi-tenant plan service (repro.serve)")
    print("=" * 70)
    # A service admits requests for many program structures concurrently
    # and resolves each through the full cache hierarchy: per-tenant plan
    # LRU -> structural compile cache -> trace bucket -> per-bounds tables.
    # Two bounds in the same power-of-two bucket share one jit trace, so
    # four (structure, bounds) pairs below cost two traces, and a warm mix
    # re-traces nothing (the serve_sustained_traffic bench gates this).
    from repro.serve import (
        PlanService,
        ServiceOptions,
        decode_program,
        scan_program,
    )

    with PlanService(ServiceOptions(workers=2, plan_cache_capacity=4)) as svc:
        for max_new in (12, 13):
            svc.submit(decode_program(max_new), tenant="decode", run=True)
        for horizon in (4, 5):
            svc.submit(scan_program(3, horizon), tenant="scan", run=True)
        stats = svc.drain()
    print(f"  tenants: {stats['tenants']}")
    print(
        f"  4 (structure, bounds) pairs -> jit traces={stats['traces']} "
        f"(bucket hits={stats['bucket_hits']}, "
        f"misses={stats['bucket_misses']})"
    )
    try:
        ServiceOptions(worker=4)  # typo: the accepted set is named
    except ValueError as e:
        print(f"  ServiceOptions(worker=4) -> ValueError: {e}")
    obs.reset_all()

    print()
    print("=" * 70)
    print("5. The same optimizer on a pipeline-parallel stage graph")
    print("=" * 70)
    pp_plan = plan_pipeline_sync(
        StageGraph(num_stages=6, num_microbatches=4, skips=((0, 2), (0, 3), (0, 4)))
    )
    print("  plan:", pp_plan.summary())
    print(
        "  retained events:",
        [(e.src_stmt, e.dst_stmt) for e in pp_plan.events],
    )

    print()
    print("=" * 70)
    print("6. Multi-device SPMD wavefront backend (xla_spmd)")
    print("=" * 70)
    # The fifth backend shards each level's padded lane tables across a
    # jax mesh (shard_map: per-device lane slice, one all_gather per step)
    # while the per-lane arithmetic stays the strict laundered ops — so
    # sharded executions stay bit-equal to the sequential oracle (the
    # oracle still decides semantics; the corpus checks xla_spmd
    # differentially like every other backend).  Its collective-aware cost
    # hook charges the all-gather tax against the per-lane savings, so the
    # SAME plan chunks a wide recurrence on one device but skews it on a
    # mesh.  Run with
    #     XLA_FLAGS=--xla_force_host_platform_device_count=8
    # to execute truly sharded; force_device_count(8) below pins only the
    # COST model, so the auction is visible from any process (execution
    # degrades safely to however many devices really exist).
    from repro.compile import spmd

    wide = LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, 40), (0, 96)),
    )
    p6 = plan(wide, PlanOptions(method="isd"))
    spmd.force_device_count(8)
    for backend in ("xla", "xla_spmd"):
        (r,) = p6.compile(backend).report().summary()["scc"]["recurrences"]
        offers = {k: round(v) for k, v in r["offers"].items()}
        print(f"  {backend:<10s} strategy={r['strategy']} offers={offers}")
    out = p6.compile("xla_spmd").run()
    print(
        "  xla_spmd bit-equal to sequential oracle:",
        out == run_sequential(wide, wide.initial_store()),
        f"(sharded over {spmd.shard_count()} device(s); cost model assumed "
        f"{spmd.device_count()})",
    )
    spmd.force_device_count(None)
    obs.reset_all()

    print()
    print("=" * 70)
    print("7. Calibration: measured per-host cost profiles (repro.calibrate)")
    print("=" * 70)
    # The cost hooks above priced every auction with hand-set default
    # units.  repro.calibrate replaces them with MEASURED ones: a tiny
    # microbenchmark suite runs through the real lowering machinery,
    # fits per-level step/lane unit costs, and persists the profile
    # keyed by a host fingerprint — so the next process (a serving
    # restart) reloads it with zero re-measurement.  Only offer PRICES
    # respond; the offer set, structural cache keys, and traces never
    # see the profile (REPRO_CALIBRATE=off pins the defaults).
    import os
    import tempfile

    from repro import calibrate
    from repro.core import clear_analysis_cache

    with tempfile.TemporaryDirectory() as cal_dir:
        os.environ["REPRO_CALIBRATE_DIR"] = cal_dir
        try:
            p7 = plan(wide, PlanOptions(method="isd"))
            (r0,) = p7.compile("xla").report().summary()["scc"]["recurrences"]
            print(
                f"  default model:    offers="
                f"{ {k: round(v) for k, v in r0['offers'].items()} } "
                f"(profile_generation={r0['profile_generation']})"
            )
            prof = calibrate.measure(n=256, widths=(4, 16), repeats=1)
            print(
                "  measured units:  "
                + " ".join(f"{k}={v:.3g}" for k, v in prof.units.items())
            )
            clear_analysis_cache()  # fresh auction under the new prices
            (r1,) = p7.compile("xla").report().summary()["scc"]["recurrences"]
            print(
                f"  calibrated:       offers="
                f"{ {k: round(v) for k, v in r1['offers'].items()} } "
                f"(profile_generation={r1['profile_generation']})"
            )
            # "restart": in-memory state gone, the profile file survives —
            # warm() reloads it without re-running the microbenchmarks
            # (PlanService(ServiceOptions(warm_profile=True)) does this
            # at startup).
            calibrate.reset()
            again = calibrate.warm()
            print(
                f"  after restart:    warm() -> source={again.source} "
                f"generation={again.generation} from "
                f"{calibrate.profile_path().name} (zero re-measurement)"
            )
        finally:
            os.environ.pop("REPRO_CALIBRATE_DIR", None)
            calibrate.reset()
            obs.reset_all()


if __name__ == "__main__":
    main()
