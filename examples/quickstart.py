"""Quickstart: the paper's algorithms end-to-end on its own examples.

    PYTHONPATH=src python examples/quickstart.py

Walks Alg.1 → Alg.3 (fission), Alg.4 → Alg.5 (send/wait insertion), and the
Alg.6/Fig.6 synchronization elimination, executing everything on real
threads and validating against sequential semantics.
"""

from repro.core import (
    StageGraph,
    analyze,
    fission,
    paper_alg1,
    paper_alg4,
    paper_alg6,
    parallelize,
    plan_pipeline_sync,
    run_threaded,
)
from repro.core.dependence import paper_alg4_dependences
from repro.core.sync import insert_synchronization


def main() -> None:
    print("=" * 70)
    print("1. Alg.1 -> Alg.2/3: dependence analysis + loop fission (Fig. 3)")
    print("=" * 70)
    prog = paper_alg1()
    for d in analyze(prog):
        print("  dep:", d.pretty())
    res = fission(prog)
    print("  fissioned loops:", res.loop_names(), "(paper: [S2],[S1,S4],[S3])")

    print()
    print("=" * 70)
    print("2. Alg.4 -> Alg.5: send/wait synchronization (Fig. 5)")
    print("=" * 70)
    prog4 = paper_alg4()
    sync = insert_synchronization(prog4, paper_alg4_dependences())
    print(sync.pretty())
    print()
    print("  NOTE: our analyzer additionally finds", end=" ")
    extra = [
        d for d in analyze(prog4)
        if (d.source, d.sink, d.array) == ("S2", "S1", "b")
    ]
    print(extra[0].pretty(), "- missing from the paper's Alg.5 (race demo in tests).")

    print()
    print("=" * 70)
    print("3. Alg.6: synchronization elimination (Fig. 6)")
    print("=" * 70)
    rep = parallelize(paper_alg6(8), method="isd")
    print("  summary:", rep.summary())
    for dep, path in rep.elimination.witnesses.items():
        chain = " -> ".join(f"{s}({i[0]})" for s, i in path)
        print(f"  eliminated {dep.pretty()}")
        print(f"  witness:   {chain}")
    run = run_threaded(rep.optimized_sync, stalls={("S3", (1,)): 0.05})
    print(
        f"  threaded execution matches sequential: {run.matches_sequential} "
        f"(waits={run.stats.waits}, sends={run.stats.sends})"
    )

    print()
    print("=" * 70)
    print("4. The same optimizer on a pipeline-parallel stage graph")
    print("=" * 70)
    plan = plan_pipeline_sync(
        StageGraph(num_stages=6, num_microbatches=4, skips=((0, 2), (0, 3), (0, 4)))
    )
    print("  plan:", plan.summary())
    print(
        "  retained events:",
        [(e.src_stmt, e.dst_stmt) for e in plan.events],
    )


if __name__ == "__main__":
    main()
