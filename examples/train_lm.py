"""End-to-end training driver: data pipeline → train loop → checkpoints →
fault recovery, for any assigned architecture family.

    PYTHONPATH=src python examples/train_lm.py --arch yi_6b --steps 60
    PYTHONPATH=src python examples/train_lm.py --arch mixtral_8x7b --steps 40 \
        --microbatches 2 --inject-failure 25

Defaults run a CPU-sized reduced config of the chosen family (the full
published configs are exercised by the multi-pod dry-run, not trainable on a
CPU container); ``--width-mult`` scales toward the ~100M regime on real
hardware.  Checkpoints land in ``/tmp/repro_ckpt_<arch>`` and the run resumes
from them when re-invoked.
"""

import argparse
import dataclasses
import tempfile

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHITECTURES, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.optim.optimizer import AdamW
from repro.runtime.fault_tolerance import WorkerFailure
from repro.runtime.trainer import train_loop


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi_6b", choices=ARCHITECTURES)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--width-mult", type=int, default=1,
                    help="multiply d_model/d_ff (scale toward ~100M params)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a worker failure at this step (recovery demo)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.width_mult > 1:
        cfg = cfg.scaled(
            d_model=cfg.d_model * args.width_mult,
            d_ff=cfg.d_ff * args.width_mult,
            head_dim=cfg.head_dim * args.width_mult,
        )
    data_cfg = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=0)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix=f"repro_ckpt_{args.arch}_")
    ckpt = CheckpointManager(ckpt_dir, keep=3)
    opt = AdamW(learning_rate=args.lr, warmup_steps=10, total_steps=args.steps)

    fired = []

    def injector(step):
        if args.inject_failure is not None and step == args.inject_failure and not fired:
            fired.append(True)
            print(f"!! injecting WorkerFailure at step {step}")
            raise WorkerFailure("w0")

    print(f"training {cfg.name} ({args.steps} steps, ckpt: {ckpt_dir})")
    res = train_loop(
        cfg,
        data_cfg,
        total_steps=args.steps,
        ckpt=ckpt,
        ckpt_every=args.ckpt_every,
        opt=opt,
        microbatches=args.microbatches,
        failure_injector=injector if args.inject_failure else None,
    )
    print(
        f"done: step={res.final_step} restarts={res.restarts} "
        f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}"
    )
    for i in range(0, len(res.losses), max(1, len(res.losses) // 10)):
        print(f"  step {i:4d}  loss {res.losses[i]:.4f}")
    ckpt.close()


if __name__ == "__main__":
    main()
