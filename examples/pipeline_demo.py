"""Pipeline-parallel demo: the paper's sync optimizer planning a whisper-like
stage graph (encoder output fanning out to every decoder stage), executed by
the DSWP thread runner with only the retained hand-offs.

    PYTHONPATH=src python examples/pipeline_demo.py --stages 6 --microbatches 8
"""

import argparse

import jax
import jax.numpy as jnp

from repro.runtime.pipeline import PipelineRunner


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--stages", type=int, default=6)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--fanout-from", type=int, default=0,
                    help="stage whose output every later stage consumes")
    args = ap.parse_args()

    S = args.stages
    skips = tuple((args.fanout_from, d) for d in range(args.fanout_from + 2, S))

    # stage functions: tiny jit'd MLPs (skip inputs are summed in)
    keys = jax.random.split(jax.random.PRNGKey(0), S)
    ws = [jax.random.normal(k, (16, 16)) * 0.3 for k in keys]

    def mk(s):
        @jax.jit
        def fn(x):
            if isinstance(x, tuple):
                base, *sk = x
                x = base + sum(sk)
            return jnp.tanh(x @ ws[s])

        return fn

    runner = PipelineRunner(
        [mk(s) for s in range(S)], skips=skips, num_microbatches=args.microbatches
    )
    print("stage graph:", S, "stages; skip edges:", skips)
    print("sync plan:", runner.plan.summary())

    inputs = [
        jax.random.normal(jax.random.fold_in(keys[0], m), (4, 16))
        for m in range(args.microbatches)
    ]
    out, stats = runner.run(inputs)
    ref = runner.run_reference(inputs)
    ok = all(
        bool(jnp.allclose(a, b, atol=1e-5)) for a, b in zip(out, ref)
    )
    print(
        f"executed {stats.microbatches} microbatches over {stats.stages} stages: "
        f"{stats.handoffs} hand-offs ({stats.handoffs_per_microbatch:.0f}/microbatch; "
        f"naive schedule: {runner.naive_handoffs_per_microbatch()}/microbatch)"
    )
    print("matches sequential reference:", ok)


if __name__ == "__main__":
    main()
