"""Benchmark harness — one function per paper figure/example plus the
framework-integration benches.  Prints ``name,us_per_call,derived`` CSV;
``--json BENCH_sync.json`` additionally writes a machine-readable record
``{name: {"us_per_call": float, "derived": str, "ratio": float?}}``
(uploaded as a CI artifact, the perf-trajectory data points), and
``--reports PATH`` writes the ``ParallelizationReport.summary()`` JSON of
the benchmark programs (strategy selection, SCC partitions, cache counters)
so strategy-drift across PRs is diffable as a CI artifact.

Regression gate: ``--check-baseline`` compares this run's key benches
(:data:`KEY_BENCHES`) against the committed record
``benchmarks/BASELINE.json`` and exits non-zero on a regression (CI fails
the build).  Benches that record a same-process **ratio** (hybrid/threaded,
skew/chunk — both sides measured back to back in this interpreter) are
gated on the ratio directly, which no amount of absolute runner-speed noise
can move; the remaining key benches gate on ``us_per_call`` after
normalizing out runner speed against :data:`CALIBRATION_BENCHES`.  After an
intentional perf change, refresh the record with
``python benchmarks/run.py --update-baseline`` and commit the diff.

Paper benches (the paper's "results" are its didactic examples, so each
bench reproduces one and reports the paper's implied metric — synchronization
operations before/after optimization — plus wall time of the transformation
itself):

  fission_alg1          §3.1 Fig. 3: Alg.1 → Alg.3 loop structure
  sync_insertion_alg4   §4.1 Fig. 5: Alg.4 → Alg.5 send/wait counts
  elim_tr_alg6          §4.2 Fig. 6: ISD transitive reduction
  elim_pattern_alg6     §4.2: pattern-matching elimination
  elim_scaling          elimination rate/throughput on random programs
  executor_sync_ops     runtime sync events, naive vs optimized (threads)

Integration benches (the technique lifted into the distributed runtime):

  pp_schedule           stage-graph sync plans: naive vs reduced events
  kernel_pipeline       K-loop plan: buffer depth / credit-wait theorem
  grad_sync_batching    gradient-accumulation sync batching + compression

Compile-cache benches (the repro.compile subsystem):

  xla_vs_wavefront_alg6_1024  warm jitted XLA level loop vs NumPy wavefront
  compile_cache_cold_warm     cold (analyze+lower+jit) vs warm (cache hit)
  kloop_structural_cache      K-loop re-plans across steps: structural hits

Cyclic-dependence benches (the SCC-condensed hybrid + the scheduling-policy
engine, repro.core.scc / repro.core.policy):

  cyclic_recurrence_1024      mixed-sign (1,-1) recurrence @ 1024 iterations:
                              chunked-DOACROSS hybrid vs the threaded machine
                              (ratio-gated: hybrid/threaded, same process)
  scc_hybrid_pipeline         recurrence SCC + DOALL consumer: cross-SCC
                              pipelining depth vs blocked execution
  skew_vs_chunk_wide          wide-inner-dimension recurrence whose (0,1)
                              carried dep pins chunks to 1: the cost model
                              must pick the unimodular skew and beat forced
                              chunking (ratio-gated: skew/chunk)
  xla_policy_backend_aware    ONE SyncPlan compiled for wavefront AND xla:
                              the backend level_cost hooks pick different
                              strategies for the same SCC (skew vs chunk),
                              both bit-equal to the oracle; summaries ride
                              the SYNC_REPORTS artifact (backend_aware_*)
  spmd_wide_wavefront         ONE SyncPlan compiled for xla AND xla_spmd
                              under 8 virtual host devices: the
                              collective-aware cost hook skews the wide
                              recurrence on the mesh while single-device
                              xla chunks it (and a narrow blocked
                              recurrence keeps chunking on the mesh);
                              ratio-gated spmd/xla against the committed
                              baseline (see the bench's honesty note on
                              virtual-device core sharing)

Serving bench (the repro.serve plan service):

  serve_sustained_traffic     two epochs of a fixed structure-and-bucket
                              mix through a PlanService: requests/sec,
                              p50/p99 latency, warm-epoch re-trace count
                              (asserted 0 — shape-bucketed traced
                              artifacts) — ratio-gated warm/cold; its
                              stats snapshot is the --serve / SERVE_sync
                              artifact
"""

from __future__ import annotations

import importlib.util
import json
import os
import pathlib
import sys
import time
from typing import Callable, Dict, List

import numpy as np

# the spmd_wide_wavefront bench shards over 8 virtual host devices; the
# flag must be in XLA_FLAGS before jax initializes (CI's full job exports
# it too — this merge makes a bare `python benchmarks/run.py` equivalent),
# and an explicit user-provided device count is left alone
if "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

if importlib.util.find_spec("repro") is None:  # run from a bare checkout
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

ROWS: List[Dict[str, object]] = []


def _timeit(fn: Callable, n: int = 5) -> float:
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # µs


def _best_of(fn: Callable, n: int = 5) -> float:
    """min-of-n per-call time in µs (steadier than the mean under CI load)."""

    fn()  # warmup
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row(
    name: str, us: float, derived: str, ratio: float | None = None
) -> None:
    """Record one bench.  ``ratio`` is an optional same-process comparative
    metric (e.g. hybrid/threaded) — self-normalizing, so the regression gate
    prefers it over ``us_per_call`` when the baseline carries one too."""

    row: Dict[str, object] = {
        "name": name, "us_per_call": round(us, 1), "derived": derived,
    }
    if ratio is not None:
        row["ratio"] = round(ratio, 4)
    ROWS.append(row)
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------- #

def bench_fission_alg1() -> None:
    from repro.core import fission, paper_alg1

    prog = paper_alg1(64)
    us = _timeit(lambda: fission(prog))
    res = fission(prog)
    loops = "+".join("".join(n[1] for n in l) for l in res.loop_names())
    _row(
        "fission_alg1",
        us,
        f"loops={loops} (paper: 2+14+3) "
        f"all_parallel={all(l.parallel for l in res.loops)}",
    )


def bench_sync_insertion_alg4() -> None:
    from repro.core import analyze, insert_synchronization, paper_alg4
    from repro.core.dependence import paper_alg4_dependences

    prog = paper_alg4(64)
    us = _timeit(lambda: insert_synchronization(prog, analyze(prog)))
    paper = insert_synchronization(prog, paper_alg4_dependences())
    ours = insert_synchronization(prog, analyze(prog))
    _row(
        "sync_insertion_alg4",
        us,
        f"paper_alg5_instructions={paper.sync_instruction_count()['total']} "
        f"full_graph_instructions={ours.sync_instruction_count()['total']} "
        f"(paper misses S2-δf1->S1)",
    )


def bench_elim_tr_alg6() -> None:
    from repro.core import analyze, eliminate_transitive, paper_alg6

    prog = paper_alg6(64)
    deps = analyze(prog)
    us = _timeit(lambda: eliminate_transitive(prog, deps))
    res = eliminate_transitive(prog, deps)
    (path,) = res.witnesses.values()
    _row(
        "elim_tr_alg6",
        us,
        f"eliminated={len(res.eliminated)}/2 retained={len(res.retained)} "
        f"witness_len={len(path)} (Fig.6 chain)",
    )


def bench_elim_pattern_alg6() -> None:
    from repro.core import analyze, eliminate_pattern, paper_alg6

    prog = paper_alg6(64)
    deps = analyze(prog)
    us = _timeit(lambda: eliminate_pattern(prog, deps))
    res = eliminate_pattern(prog, deps)
    _row(
        "elim_pattern_alg6",
        us,
        f"eliminated={len(res.eliminated)}/2 via 5-condition match",
    )


def bench_elim_scaling() -> None:
    import random

    from repro.core import ArrayRef, LoopProgram, Statement, plan

    rng = random.Random(0)
    arrays = ["a", "b", "c", "d", "e"]
    total_deps = total_elim = 0
    t_us: List[float] = []
    for trial in range(20):
        stmts = []
        for k in range(6):
            reads = tuple(
                ArrayRef(rng.choice(arrays), -rng.randint(0, 3))
                for _ in range(rng.randint(1, 3))
            )
            stmts.append(Statement(f"S{k+1}", ArrayRef(arrays[k % 5], 0), reads))
        prog = LoopProgram(statements=tuple(stmts), bounds=((1, 9),))
        t0 = time.perf_counter()
        rep = plan(prog, method="both").compile("threaded").report()
        t_us.append((time.perf_counter() - t0) * 1e6)
        total_deps += rep.summary()["loop_carried"]
        total_elim += rep.summary()["eliminated"]
    _row(
        "elim_scaling",
        float(np.mean(t_us)),
        f"random_programs=20 carried_deps={total_deps} "
        f"eliminated={total_elim} ({100*total_elim/max(total_deps,1):.0f}%)",
    )


def bench_wavefront_speedup() -> None:
    """Threaded send/wait machine vs wavefront backend on the paper's Alg. 6
    loop at 1024 iterations: wall time, runtime sync ops (naive/optimized)
    and the wavefront's barrier count (its only synchronization)."""

    from repro.core import paper_alg6, plan, run_threaded, run_wavefront

    rep = plan(paper_alg6(1025), method="isd").compile("wavefront").report()
    t0 = time.perf_counter()
    run_threaded(rep.optimized_sync, compare=False, timeout=120.0)
    t_threaded = time.perf_counter() - t0
    t_wavefront = (
        _best_of(
            lambda: run_wavefront(
                rep.optimized_sync, schedule=rep.wavefront, compare=False
            ),
            n=7,
        )
        / 1e6
    )
    s = rep.summary()
    _row(
        "wavefront_speedup_alg6_1024",
        t_wavefront * 1e6,
        f"threaded_ms={t_threaded*1e3:.1f} wavefront_ms={t_wavefront*1e3:.1f} "
        f"speedup={t_threaded/t_wavefront:.1f}x "
        f"naive_sync_ops={s['naive_runtime_sync_ops']} "
        f"optimized_sync_ops={s['optimized_runtime_sync_ops']} "
        f"wavefront_barriers={rep.wavefront.depth}",
    )


def bench_wavefront_parallel_loop() -> None:
    """A dependence-free (DOALL) 1024-iteration loop: the wavefront collapses
    to depth == #statements with iteration-wide batches."""

    from repro.core import ArrayRef, LoopProgram, Statement, plan, run_wavefront

    prog = LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), (ArrayRef("b", 0),)),
            Statement("S2", ArrayRef("c", 0), (ArrayRef("a", 0), ArrayRef("b", 0))),
        ),
        bounds=((0, 1024),),
    )
    rep = plan(prog, method="isd").compile("wavefront").report()
    us = _timeit(
        lambda: run_wavefront(rep.optimized_sync, schedule=rep.wavefront, compare=False),
        n=3,
    )
    wf = rep.wavefront
    _row(
        "wavefront_parallel_1024",
        us,
        f"depth={wf.depth} batched_ops={wf.batched_ops} "
        f"instances={wf.instances} max_width={wf.max_width}",
    )


def bench_xla_vs_wavefront() -> None:
    """Acceptance bench: the warm-cache jitted XLA level loop must beat the
    NumPy wavefront interpreter on Alg. 6 @ 1024 iterations (same schedule,
    same store format).  Measurements are *interleaved* min-of-7 so machine
    load inflates both sides equally instead of flipping the ratio."""

    from repro.compile import run_xla
    from repro.core import paper_alg6, plan, run_wavefront

    rep = plan(paper_alg6(1025), method="isd").compile("xla").report()
    wrep = plan(paper_alg6(1025), method="isd").compile("wavefront").report()
    fn_xla = lambda: run_xla(rep.optimized_sync, compare=False)
    fn_np = lambda: run_wavefront(
        wrep.optimized_sync, schedule=wrep.wavefront, compare=False
    )
    fn_xla(), fn_np()  # warm both
    t_xla = t_np = float("inf")
    for _ in range(9):  # raised min-of-n: key bench, judged by the gate
        t0 = time.perf_counter()
        fn_xla()
        t_xla = min(t_xla, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_np()
        t_np = min(t_np, time.perf_counter() - t0)
    t_xla *= 1e6
    t_np *= 1e6
    cc = rep.compiled.cache_stats()
    _row(
        "xla_vs_wavefront_alg6_1024",
        t_xla,
        f"wavefront_us={t_np:.0f} xla_us={t_xla:.0f} "
        f"speedup={t_np / t_xla:.2f}x levels={wrep.wavefront.depth} "
        f"cache_hits={cc['hits']} cache_misses={cc['misses']}",
    )


def bench_compile_cache_cold_warm() -> None:
    """Cold (schedule + lowering + jit trace) vs warm (structural + table
    hit) cost of the xla path, plus the counters after the sequence."""

    from repro.compile import clear_compile_cache, compile_cache_stats, run_xla
    from repro.core import paper_alg6, plan

    clear_compile_cache()
    rep = plan(paper_alg6(257), method="isd").compile("xla").report()
    t0 = time.perf_counter()
    run_xla(rep.optimized_sync, compare=False)
    cold_us = (time.perf_counter() - t0) * 1e6
    warm_us = _best_of(
        lambda: run_xla(rep.optimized_sync, compare=False), n=5
    )
    s = compile_cache_stats()
    _row(
        "compile_cache_cold_warm",
        warm_us,
        f"cold_us={cold_us:.0f} warm_us={warm_us:.0f} "
        f"cold_over_warm={cold_us / warm_us:.1f}x "
        f"hits={s['hits']} misses={s['misses']} "
        f"table_hits={s['table_hits']} table_misses={s['table_misses']}",
    )


def bench_kloop_structural_cache() -> None:
    """Re-planning the Pallas K-loop across different ``steps`` is a
    structural hit (the key excludes bounds); changing the buffer depth
    changes the retained deps and misses."""

    from repro.kernels.pipelined_matmul.schedule import compile_kloop

    compile_kloop(2, 16)  # may hit or miss depending on suite order
    t_hit = _best_of(lambda: compile_kloop(2, 16), n=3)
    _c, hit_other_steps = compile_kloop(2, 128)
    _c, hit_other_depth = compile_kloop(1, 16)
    _row(
        "kloop_structural_cache",
        t_hit,
        f"steps_128_hit={hit_other_steps} depth_1_hit={hit_other_depth} "
        "(key excludes bounds, includes retained deps)",
    )


def _skew_recurrence_program(ni: int, nj: int):
    from repro.core import ArrayRef, LoopProgram, Statement

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
        ),
        bounds=((0, ni), (0, nj)),
    )


def bench_cyclic_recurrence() -> None:
    """Acceptance bench for the SCC hybrid: a mixed-sign (1,-1) skewed
    recurrence over 1024 iterations — rejected outright by the fast
    backends before repro.core.scc existed — as a chunked DOACROSS
    (``scc_policy="chunk"`` pins the historical strategy; the policy engine
    would pick skew here, which skew_vs_chunk_wide measures) that must beat
    the one-thread-per-iteration machine ≥ 5×.  Also reports the warm XLA
    nested-fori_loop form of the same schedule.  Gated on the same-process
    hybrid/threaded ratio."""

    from repro.compile import run_xla
    from repro.core import plan, run_threaded, run_wavefront

    prog = _skew_recurrence_program(64, 16)  # 1024 iterations, chunk 15
    rep = plan(prog, method="isd").compile("wavefront", scc_policy="chunk").report()
    (rec,) = rep.wavefront.scc.recurrences
    # min-of-3: the 1024-thread spawn storm is the ratio's noisy side
    t_threaded = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        run_threaded(rep.optimized_sync, compare=False, timeout=180.0)
        t_threaded = min(t_threaded, time.perf_counter() - t0)
    hybrid_us = _best_of(
        lambda: run_wavefront(
            rep.optimized_sync, schedule=rep.wavefront, compare=False
        ),
        n=9,
    )
    run_xla(rep.optimized_sync, schedule=rep.wavefront, compare=False)  # warm
    xla_us = _best_of(
        lambda: run_xla(
            rep.optimized_sync, schedule=rep.wavefront, compare=False
        ),
        n=9,
    )
    speedup = t_threaded * 1e6 / hybrid_us
    _row(
        "cyclic_recurrence_1024",
        hybrid_us,
        f"threaded_ms={t_threaded*1e3:.1f} hybrid_us={hybrid_us:.0f} "
        f"xla_us={xla_us:.0f} speedup={speedup:.1f}x "
        f"chunk={rec.chunk} depth={rep.wavefront.depth} "
        f"meets_5x={speedup >= 5.0}",
        ratio=hybrid_us / (t_threaded * 1e6),
    )


def bench_scc_hybrid_pipeline() -> None:
    """Recurrence SCC feeding a DOALL consumer: the consumer's batches level
    right behind each producer chunk (depth ≈ chunks + 2), instead of the
    blocked 2×chunks a run-SCCs-to-completion scheduler would produce."""

    from repro.core import ArrayRef, LoopProgram, Statement, plan, run_wavefront

    prog = LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
            Statement("S2", ArrayRef("c", (0, 0)), (ArrayRef("a", (0, 0)),)),
        ),
        bounds=((0, 64), (0, 17)),
    )
    rep = plan(prog, method="isd").compile("wavefront", scc_policy="chunk").report()
    us = _best_of(
        lambda: run_wavefront(
            rep.optimized_sync, schedule=rep.wavefront, compare=False
        ),
        n=9,
    )
    wf = rep.wavefront
    (rec,) = wf.scc.recurrences
    total = 64 * 17
    n_chunks = -(-total // rec.chunk)
    _row(
        "scc_hybrid_pipeline",
        us,
        f"depth={wf.depth} chunks={n_chunks} chunk={rec.chunk} "
        f"pipelined={wf.depth <= n_chunks + 2} "
        f"blocked_depth_would_be={2 * n_chunks}",
    )


def _wide_serialized_recurrence(ni: int, nj: int):
    """One statement carrying {(0,1), (1,-1)}: the (0,1) dep pins DOACROSS
    chunks to 1 (fully serial), while a unimodular skew runs a diagonal
    wavefront — the policy engine's motivating case."""

    from repro.core import ArrayRef, LoopProgram, Statement

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, ni), (0, nj)),
    )


def bench_skew_vs_chunk_wide() -> None:
    """Policy-engine acceptance: on a wide inner dimension the cost model
    must pick the unimodular skew and beat forced chunking.  Both sides are
    measured in this process back to back, so the gate judges the
    skew/chunk ratio — runner speed cancels exactly."""

    from repro.core import plan, run_wavefront

    # 8192 iterations, inner dimension 128 wide; the (0,1) dep serializes
    # chunked execution into 8192 unit chunks while the skew wavefronts
    # stay ~32 instances wide
    prog = _wide_serialized_recurrence(64, 128)
    rep_auto = plan(prog, method="isd").compile("wavefront").report()
    rep_chunk = plan(prog, method="isd").compile("wavefront", scc_policy="chunk").report()
    (rec,) = rep_auto.wavefront.scc.recurrences
    skew_us = _best_of(
        lambda: run_wavefront(
            rep_auto.optimized_sync, schedule=rep_auto.wavefront, compare=False
        ),
        n=9,
    )
    chunk_us = _best_of(
        lambda: run_wavefront(
            rep_chunk.optimized_sync,
            schedule=rep_chunk.wavefront,
            compare=False,
        ),
        n=9,
    )
    ratio = skew_us / chunk_us
    _row(
        "skew_vs_chunk_wide",
        skew_us,
        f"picked={rec.strategy} skew_depth={rep_auto.wavefront.depth} "
        f"chunk_depth={rep_chunk.wavefront.depth} chunk_us={chunk_us:.0f} "
        f"skew_over_chunk={ratio:.3f} policy_beats_chunk={ratio < 1.0}",
        ratio=ratio,
    )


def bench_xla_policy_backend_aware() -> None:
    """Backend-aware cost-model acceptance: ONE SyncPlan, two backends, two
    *different* strategies for the same recurrence SCC — the NumPy
    interpreter (cost = depth × groups) skews the scan; the compiled level
    loop (``repro.compile.xla_level_cost``: near-flat step cost + padded
    lane width) chunks it, because the skewed diagonals pad to 64 lanes.
    Both choices are asserted bit-equal to the sequential oracle; the row's
    ratio is warm xla / warm wavefront (same process, runner speed
    cancels).  The report summaries of both compiles ride the SYNC_REPORTS
    artifact (collect_reports: backend_aware_40x96_*)."""

    from repro.core import plan, run_sequential

    prog = _wide_serialized_recurrence(40, 96)
    p = plan(prog, method="isd")
    exe_wf = p.compile("wavefront")
    exe_xla = p.compile("xla")
    (rec_wf,) = exe_wf.report().summary()["scc"]["recurrences"]
    (rec_xla,) = exe_xla.report().summary()["scc"]["recurrences"]
    assert (rec_wf["strategy"], rec_xla["strategy"]) == ("skew", "chunk"), (
        "backend-aware divergence lost",
        rec_wf["strategy"],
        rec_xla["strategy"],
    )
    init = prog.initial_store()
    oracle = run_sequential(prog, init)
    assert exe_wf.run(store=init) == oracle, "wavefront diverged from oracle"
    assert exe_xla.run(store=init) == oracle, "xla diverged from oracle"
    wf_us = _best_of(lambda: exe_wf.run(store=init), n=7)
    xla_us = _best_of(lambda: exe_xla.run(store=init), n=7)
    _row(
        "xla_policy_backend_aware",
        xla_us,
        f"wavefront={rec_wf['strategy']} xla={rec_xla['strategy']} "
        f"wf_us={wf_us:.0f} xla_us={xla_us:.0f} both_bit_equal=True",
        ratio=xla_us / wf_us,
    )


def _narrow_blocked_recurrence(n: int = 32):
    """{(0,-32), (-1,1)}: the (0,-32) dep admits 32-iteration DOACROSS
    chunks, so chunking stays cheap and a skewed wavefront's lanes never
    amortize the collective tax — the case where sharding must LOSE the
    auction.  Reads reach 32 cells back: run with ``initial_store(pad=33)``.
    """

    from repro.core import ArrayRef, LoopProgram, Statement

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -32)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, n), (0, n)),
    )


def bench_spmd_wide_wavefront() -> None:
    """Multi-device SPMD acceptance: ONE SyncPlan, ``xla`` vs ``xla_spmd``
    on the wide {(0,1),(1,-1)} recurrence under 8 (virtual host) devices.
    The collective-aware cost hook diverges per SCC: single-device xla
    chunks (96 padded lanes at flat step cost beat a serial chunk walk
    only when... they don't — chunk wins), the 8-device mesh skews (lanes/8
    beats the all-gather tax).  Both are asserted bit-equal to the oracle;
    the narrow blocked recurrence is asserted to keep CHUNKING on the same
    mesh (sharding must lose that auction).

    The recorded ratio is warm spmd / warm xla, same process.  HONESTY
    NOTE: 8 virtual host devices timeshare this machine's physical cores,
    and each sharded step pays a fixed ~70–120µs shard_map dispatch vs
    ~1µs for the single-device level step — on a 1-core runner the ratio
    sits near 4x (sharding_wins=False) and ONLY drops below 1.0 when real
    cores back the mesh.  The gate therefore pins the committed baseline
    ratio (dispatch-overhead regressions move it), not ratio<1.0; derived
    records devices, cores and the sharding_wins flag so multi-core
    runners are legible in the artifact."""

    from repro.compile import spmd
    from repro.core import plan, run_sequential

    prog = _wide_serialized_recurrence(40, 96)
    p = plan(prog, method="isd")
    exe_xla = p.compile("xla")
    exe_spmd = p.compile("xla_spmd")
    (rec_x,) = exe_xla.report().summary()["scc"]["recurrences"]
    (rec_s,) = exe_spmd.report().summary()["scc"]["recurrences"]
    devices = spmd.shard_count()
    if devices >= 2:
        assert (rec_x["strategy"], rec_s["strategy"]) == ("chunk", "skew"), (
            "collective-aware divergence lost",
            rec_x["strategy"],
            rec_s["strategy"],
        )
    init = prog.initial_store()
    oracle = run_sequential(prog, init)
    assert exe_xla.run(store=init) == oracle, "xla diverged from oracle"
    assert exe_spmd.run(store=init) == oracle, "xla_spmd diverged from oracle"
    xla_us = _best_of(lambda: exe_xla.run(store=init), n=5)
    spmd_us = _best_of(lambda: exe_spmd.run(store=init), n=5)

    # the flip side: where sharding loses, the auction must keep chunking
    narrow = _narrow_blocked_recurrence(32)
    exe_narrow = plan(narrow, method="isd").compile("xla_spmd")
    (rec_n,) = exe_narrow.report().summary()["scc"]["recurrences"]
    assert rec_n["strategy"] == "chunk", (
        "narrow recurrence should keep chunking on the mesh",
        rec_n["strategy"],
    )
    narrow_init = narrow.initial_store(pad=33)
    assert exe_narrow.run(
        store={a: dict(c) for a, c in narrow_init.items()}
    ) == run_sequential(
        narrow, {a: dict(c) for a, c in narrow_init.items()}
    ), "narrow xla_spmd diverged from oracle"

    ratio = spmd_us / xla_us
    _row(
        "spmd_wide_wavefront",
        spmd_us,
        f"devices={devices} cores={os.cpu_count()} "
        f"xla={rec_x['strategy']} spmd={rec_s['strategy']} "
        f"narrow_spmd={rec_n['strategy']} xla_us={xla_us:.0f} "
        f"spmd_us={spmd_us:.0f} sharding_wins={ratio < 1.0} "
        f"both_bit_equal=True",
        ratio=ratio,
    )


def bench_inspector_sparse_matvec() -> None:
    """Inspector-executor value bench: COO sparse matvec
    ``y[row[k]] += v[k]*x[col[k]]`` with 512 nonzeros over 64 distinct rows
    (8 hits each).  The conservative non-affine proxy chain serializes all
    512 iterations; ``deps="inspect"`` layers the exact instance graph
    instead (depth = max row multiplicity = 8).  Both sides execute warm in
    THIS process on the wavefront backend, so the recorded ratio
    (inspect / serialized) is runner-speed-free.  Bit-equality to the
    sequential oracle is asserted before timing.  In KEY_BENCHES since PR 7
    (its baseline row was seeded by PR 6): a broken inspector schedule
    moves this ratio toward 1.0 from above or serializes it entirely.
    """

    from repro.core import (
        PlanOptions,
        indexed_store,
        inspect_dependences,
        plan,
        run_sequential,
        sparse_matvec,
    )

    n, distinct_rows = 512, 64
    prog = sparse_matvec(n)
    store = indexed_store(
        prog,
        {
            "row": [k % distinct_rows for k in range(n)],
            "col": [(3 * k) % n for k in range(n)],
        },
    )
    exe_serial = plan(prog).compile("wavefront")
    exe_inspect = plan(prog, PlanOptions(deps="inspect")).compile("wavefront")
    init = {a: dict(c) for a, c in store.items()}
    oracle = run_sequential(prog, init)
    assert exe_serial.run(store=init) == oracle, "serialized diverged"
    assert exe_inspect.run(store=init) == oracle, "inspected diverged"
    serial_us = _best_of(lambda: exe_serial.run(store=init), n=5)
    inspect_us = _best_of(lambda: exe_inspect.run(store=init), n=5)
    edges = len(inspect_dependences(prog, store).edges)
    ratio = inspect_us / serial_us
    _row(
        "inspector_sparse_matvec",
        inspect_us,
        f"n={n} distinct_rows={distinct_rows} instance_edges={edges} "
        f"serialized_us={serial_us:.0f} inspect_us={inspect_us:.0f} "
        f"parallel_over_serialized={ratio:.3f} both_bit_equal=True",
        ratio=ratio,
    )


# populated by bench_serve_sustained_traffic; written by --serve (the
# SERVE_sync CI artifact: the PlanService.stats() snapshot after the bench)
SERVE_STATS: Dict[str, object] = {}


def bench_serve_sustained_traffic() -> None:
    """Sustained-traffic serving acceptance: two epochs of a fixed
    structure-and-bucket mix through one ``PlanService``.  Epoch 1 (cold)
    pays analysis, lowering and every bucket's jit trace; epoch 2 replays
    the *identical* mix and must perform ZERO new jit traces (shape-bucketed
    traced artifacts — asserted in-process, not just gated).  The recorded
    ratio is warm/cold epoch wall time, both sides in this interpreter, so
    a bucketing regression (warm waves re-tracing) drags it toward 1.0 no
    matter how fast the runner is.  Derived carries the serving metrics the
    snapshot artifact (``--serve`` / SERVE_sync) records in full:
    warm-epoch requests/sec and whole-run p50/p99 request latency (the p99
    is a cold-epoch trace, by construction)."""

    from repro.obs import metrics, reset_all
    from repro.serve import PlanService, ServiceOptions, decode_program, scan_program

    reset_all()
    # the fixed mix: two structures x two bounds in the same pow2 bucket
    mix = (
        [(decode_program(b), "decode") for b in (12, 13)]
        + [(scan_program(3, h), "scan") for h in (4, 5)]
    )
    waves = 8
    svc = PlanService(ServiceOptions(workers=4))

    def epoch() -> float:
        t0 = time.perf_counter()
        for _ in range(waves):
            futs = [
                svc.submit(prog, tenant=tenant, run=True)
                for prog, tenant in mix
            ]
            for f in futs:
                f.result()
        return (time.perf_counter() - t0) * 1e6

    cold_us = epoch()
    traces_after_cold = metrics.counter("xla.traces").value
    warm_us = epoch()
    retraces = metrics.counter("xla.traces").value - traces_after_cold
    assert retraces == 0, (
        f"warm epoch re-traced {retraces} time(s) — shape bucketing broken"
    )
    SERVE_STATS.update(svc.drain())
    svc.close()
    requests = waves * len(mix)
    lat = metrics.histogram("serve.latency_ms.decode")
    p50, p99 = lat.percentile(50), lat.percentile(99)
    ratio = warm_us / cold_us
    _row(
        "serve_sustained_traffic",
        warm_us / requests,
        f"requests_per_epoch={requests} warm_rps={requests / (warm_us / 1e6):.0f} "
        f"p50_ms={p50:.2f} p99_ms={p99:.2f} "
        f"warm_retraces={retraces} traces={traces_after_cold} "
        f"warm_over_cold={ratio:.3f}",
        ratio=ratio,
    )


def bench_executor_sync_ops() -> None:
    from repro.core import paper_alg6, plan, run_threaded

    rep = plan(paper_alg6(10), method="isd").compile("threaded").report()
    naive = run_threaded(rep.naive_sync)
    opt = run_threaded(rep.optimized_sync)
    assert naive.matches_sequential and opt.matches_sequential
    us = _timeit(lambda: run_threaded(rep.optimized_sync), n=3)
    _row(
        "executor_sync_ops",
        us,
        f"naive_waits={naive.stats.waits} optimized_waits={opt.stats.waits} "
        f"naive_sends={naive.stats.sends} optimized_sends={opt.stats.sends} "
        f"both_match_sequential=True",
    )


# ---------------------------------------------------------------------- #

def bench_pp_schedule() -> None:
    from repro.core import StageGraph, plan_pipeline_sync

    for S, skips in [(8, 6), (16, 14), (32, 30)]:
        graph = StageGraph(
            num_stages=S,
            num_microbatches=8,
            skips=tuple((0, d) for d in range(2, 2 + skips)),
        )
        t0 = time.perf_counter()
        plan = plan_pipeline_sync(graph)
        us = (time.perf_counter() - t0) * 1e6
        s = plan.summary()
        naive, opt = s["synchronized_deps_naive"], s["synchronized_deps_optimized"]
        _row(
            f"pp_schedule_S{S}",
            us,
            f"naive_syncs={naive} optimized={opt} "
            f"reduction={100*(naive-opt)/naive:.0f}%",
        )


def bench_kernel_pipeline() -> None:
    from repro.kernels.pipelined_matmul.schedule import min_buffers, plan_pipeline

    us = _timeit(lambda: plan_pipeline(2))
    p1, p2 = plan_pipeline(1), plan_pipeline(2)
    _row(
        "kernel_pipeline",
        us,
        f"depth1_credit_wait={p1.credit_wait_needed} "
        f"depth2_credit_wait={p2.credit_wait_needed} min_buffers={min_buffers()}",
    )


def bench_grad_sync_batching() -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model_zoo as zoo
    from repro.optim.compression import Int8Compressor, TopKCompressor

    cfg = get_smoke_config("yi_6b")
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    n = zoo.param_count(params)
    f32_bytes = 4 * n
    for k in (1, 4, 16):
        # one all-reduce of the summed gradient instead of k — the paper's
        # single-sync-for-many-dependences, lifted to DP
        _row(
            f"grad_sync_batching_k{k}",
            0.0,
            f"all_reduce_bytes_naive={k*f32_bytes} optimized={f32_bytes} "
            f"reduction={100*(1-1/k):.0f}%",
        )
    g = {"g": jnp.ones((n,), jnp.float32)}
    int8 = Int8Compressor()
    topk = TopKCompressor(fraction=0.01)
    _row(
        "grad_compression",
        0.0,
        f"f32_bytes={int8.raw_bytes(g)} int8={int8.compressed_bytes(g)} "
        f"top1pct={topk.compressed_bytes(g)}",
    )


def bench_roofline_summary() -> None:
    """Per-cell dominant-term summary from the saved dry-run records (skips
    gracefully when the dry-run has not been executed in this checkout)."""

    import json
    import pathlib

    d = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        _row("roofline_summary", 0.0, "no dryrun records (run repro.launch.dryrun)")
        return
    doms = {"compute": 0, "memory": 0, "collective": 0}
    fits = 0
    cells = 0
    for f in sorted(d.glob("*__pod16x16.json")):
        r = json.loads(f.read_text())
        if "skipped" in r:
            continue
        cells += 1
        doms[r["roofline_analytic"]["dominant"]] += 1
        mem = r.get("memory_deploy") or r.get("memory", {})
        total = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        fits += int(total <= 16e9)
    _row(
        "roofline_summary",
        0.0,
        f"cells={cells} dominant:compute={doms['compute']} "
        f"memory={doms['memory']} collective={doms['collective']} "
        f"fit16GB={fits}/{cells} (CPU buffer-assignment caveat: EXPERIMENTS.md)",
    )


def bench_width_split_band() -> None:
    """ROADMAP 3b acceptance: width-split band lowering on a forced-wide
    skewed recurrence (96×192 — the diagonal ramps 1..96, padding every
    level to 128 lanes without the ladder).  Split (default) and unsplit
    (``WIDTH_LADDER_RUNGS = 0``) artifacts are built in LOCAL caches and
    timed on the jitted callable directly — the O(cells) host wrapper
    would bury the per-level lane saving — after asserting the two stores
    bit-equal.  Ratio-gated split/unsplit (same process, same bounds)."""

    import jax
    import jax.numpy as jnp

    from repro.core import analyze, insert_synchronization
    from repro.core.wavefront import _DenseStore
    from repro.compile import lowering
    from repro.compile.cache import CompileCache
    from repro.compile.executor import run_xla

    prog = _wide_serialized_recurrence(96, 192)
    sync = insert_synchronization(prog, analyze(prog))
    store = prog.initial_store()

    def jit_best_us(rungs: int, reps: int = 15) -> tuple:
        saved = lowering.WIDTH_LADDER_RUNGS
        lowering.WIDTH_LADDER_RUNGS = rungs
        try:
            cache = CompileCache()
            rep = run_xla(
                sync, cache=cache, scc_policy="skew", compare=False,
                store=store,
            )
            comp = rep.compiled
            dense = _DenseStore({a: dict(c) for a, c in store.items()})
            case, _ = comp.prepare(sync.program, dense)
        finally:
            lowering.WIDTH_LADDER_RUNGS = saved
        with jax.experimental.enable_x64():
            dstore = {
                a: jnp.zeros((case.padded_sizes[a],), jnp.float64)
                .at[: case.flat_sizes[a]]
                .set(jnp.asarray(dense.data[a].ravel()))
                for a in case.arrays
            }
            cov = {
                a: jnp.zeros((case.padded_sizes[a],), bool)
                for a in case.sparse
            }
            args = (
                case.static,
                jnp.int64(case.n_levels),
                tuple(jnp.asarray(d) for d in case.seg_dyn),
                comp._to_device(case),
                dstore,
                cov,
                jnp.zeros((2,), bool),
                jnp.int64(0),
            )
            jax.block_until_ready(comp._jit(*args))  # warm the trace
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(comp._jit(*args))
                best = min(best, time.perf_counter() - t0)
        return best * 1e6, rep

    split_us, rep_split = jit_best_us(3)
    unsplit_us, rep_unsplit = jit_best_us(0)
    assert rep_split.store == rep_unsplit.store, (
        "width-split lowering diverged from the unsplit artifact"
    )
    ratio = split_us / unsplit_us
    _row(
        "width_split_band",
        split_us,
        f"unsplit_us={unsplit_us:.0f} rungs={lowering.WIDTH_LADDER_RUNGS} "
        f"bit_equal=True",
        ratio=ratio,
    )


# ---------------------------------------------------------------------- #

BENCHES = [
    bench_fission_alg1,
    bench_sync_insertion_alg4,
    bench_elim_tr_alg6,
    bench_elim_pattern_alg6,
    bench_elim_scaling,
    bench_executor_sync_ops,
    bench_wavefront_speedup,
    bench_wavefront_parallel_loop,
    bench_xla_vs_wavefront,
    bench_compile_cache_cold_warm,
    bench_kloop_structural_cache,
    bench_cyclic_recurrence,
    bench_scc_hybrid_pipeline,
    bench_skew_vs_chunk_wide,
    bench_xla_policy_backend_aware,
    bench_width_split_band,
    bench_spmd_wide_wavefront,
    bench_inspector_sparse_matvec,
    bench_serve_sustained_traffic,
    bench_pp_schedule,
    bench_kernel_pipeline,
    bench_grad_sync_batching,
    bench_roofline_summary,
]

# ---------------------------------------------------------------------- #
# Baseline regression gate (CI)
# ---------------------------------------------------------------------- #

# the benches whose perf CI refuses to let regress; benches that record a
# same-process ratio are judged on the ratio, the rest on normalized
# us_per_call
KEY_BENCHES = (
    "wavefront_speedup_alg6_1024",
    "xla_vs_wavefront_alg6_1024",
    "cyclic_recurrence_1024",
    "scc_hybrid_pipeline",
    "skew_vs_chunk_wide",
    "width_split_band",
    "spmd_wide_wavefront",
    "inspector_sparse_matvec",
    "serve_sustained_traffic",
)
# >30% slower than the committed baseline (after runner-speed
# normalization) fails the build
REGRESSION_TOLERANCE = 1.30
# ratio metrics are measured in one process (both sides back to back), so
# runner speed cancels; the looser bound absorbs scheduling jitter of the
# reference side on shared runners — the failures this gate exists to catch
# (a broken strategy choice, a serialized schedule) move these ratios
# 5–70×, not 2×.  cyclic_recurrence_1024 divides by the threaded machine's
# 1024-thread spawn storm, whose timing swings ~3× with machine load even
# at min-of-3, so its bound is wider than the stable-interpreter
# skew/chunk ratio's.
RATIO_TOLERANCE = 2.00
# serve_sustained_traffic divides a tiny warm epoch (sub-ms cache hits) by
# a cold epoch dominated by jit trace+compile time, both of which swing
# with runner load; the failure it gates — warm waves re-tracing — moves
# the ratio from ~0.05 toward 1.0 (and the in-bench zero-retrace assertion
# fires first anyway)
RATIO_TOLERANCES = {
    "cyclic_recurrence_1024": 4.00,
    "serve_sustained_traffic": 3.00,
    # sharded/single-device on 8 VIRTUAL host devices: the absolute ratio
    # is core-count-bound (see bench_spmd_wide_wavefront's honesty note),
    # so the gate pins relative drift of the shard_map dispatch overhead;
    # a multi-core runner only shrinks the ratio (never a false failure)
    "spmd_wide_wavefront": 3.00,
    # split/unsplit jit-only times in one process: a broken ladder (or one
    # silently pinned off) moves this ratio from ~0.6 to 1.0+, so the bound
    # must sit below 1.0/0.6 — tighter than the default
    "width_split_band": 1.50,
}
# Stable, CPU-bound, non-key transformation benches used to normalize out
# absolute machine speed: the baseline is recorded on one machine and
# checked on another (CI runner), so each key bench is judged on
# (current/baseline) ÷ geomean(current/baseline over these).  A code change
# that slows ONLY a key path still trips the gate; a uniformly slower
# runner cancels out.  The calibration factor is clamped so a degenerate
# measurement can't silently mask a real regression.
CALIBRATION_BENCHES = (
    "fission_alg1",
    "sync_insertion_alg4",
    "elim_tr_alg6",
    "elim_pattern_alg6",
)
CALIBRATION_CLAMP = (0.25, 4.0)
DEFAULT_BASELINE = pathlib.Path(__file__).resolve().parent / "BASELINE.json"


def _runner_speed(record: Dict[str, dict], base: Dict[str, dict]) -> float:
    """Geometric-mean current/baseline ratio over the calibration benches."""

    import math

    ratios = []
    for name in CALIBRATION_BENCHES:
        if name in record and name in base:
            cur = float(record[name]["us_per_call"])
            ref = float(base[name]["us_per_call"])
            if cur > 0 and ref > 0:
                ratios.append(cur / ref)
    if not ratios:
        return 1.0
    g = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    lo, hi = CALIBRATION_CLAMP
    return min(max(g, lo), hi)


def check_baseline(record: Dict[str, dict], baseline_path: pathlib.Path) -> int:
    """Compare ``record`` against the committed baseline; returns the number
    of key-bench regressions (0 = pass) after printing a verdict table."""

    if not baseline_path.exists():
        print(
            f"baseline {baseline_path} missing — run with --update-baseline "
            "and commit it",
            file=sys.stderr,
        )
        return 1
    base = json.loads(baseline_path.read_text())
    speed = _runner_speed(record, base)
    print(
        f"REGRESSION-GATE runner-speed calibration: {speed:.2f}x "
        f"(geomean over {len(CALIBRATION_BENCHES)} non-key benches)",
        file=sys.stderr,
    )
    failures = 0
    for name in KEY_BENCHES:
        if name not in base:
            print(
                f"REGRESSION-GATE {name}: not in baseline — refresh with "
                "--update-baseline",
                file=sys.stderr,
            )
            failures += 1
            continue
        if name not in record:
            print(
                f"REGRESSION-GATE {name}: bench did not run", file=sys.stderr
            )
            failures += 1
            continue
        if "ratio" in record[name] and "ratio" in base[name]:
            # same-process comparative metric: no runner-speed term at all
            cur = float(record[name]["ratio"])
            ref = float(base[name]["ratio"])
            rel = cur / ref if ref > 0 else 1.0
            limit = RATIO_TOLERANCES.get(name, RATIO_TOLERANCE)
            verdict = "OK" if rel <= limit else "REGRESSED"
            print(
                f"REGRESSION-GATE {name}: baseline_ratio={ref:.4f} "
                f"current_ratio={cur:.4f} relative={rel:.2f}x "
                f"(limit {limit:.2f}x, same-process ratio) "
                f"{verdict}",
                file=sys.stderr,
            )
            if verdict != "OK":
                failures += 1
            continue
        cur = float(record[name]["us_per_call"])
        ref = float(base[name]["us_per_call"])
        ratio = (cur / ref) / speed if ref > 0 else 1.0
        verdict = "OK" if ratio <= REGRESSION_TOLERANCE else "REGRESSED"
        print(
            f"REGRESSION-GATE {name}: baseline={ref:.1f}us "
            f"current={cur:.1f}us normalized_ratio={ratio:.2f}x "
            f"(limit {REGRESSION_TOLERANCE:.2f}x) {verdict}",
            file=sys.stderr,
        )
        if verdict != "OK":
            failures += 1
    return failures


def collect_reports() -> Dict[str, dict]:
    """``ParallelizationReport.summary()`` for the benchmark programs.

    Written by ``--reports`` and uploaded as a CI artifact so
    strategy-selection drift (which policy won which SCC, and why) is
    diffable across PRs without re-running anything.

    Every row also carries ``strategy_profile``: the cost model's predicted
    cost for EVERY strategy offer next to the measured wall time of the
    winning strategy (repro.obs.profile) — the predicted-vs-measured record
    ROADMAP item 3c asked for, and the input to the inversion gate below.
    """

    from repro.obs import profile as obs_profile
    from repro.core import paper_alg4, paper_alg6, plan

    programs = {
        "alg6_1025_isd": (paper_alg6(1025), "wavefront", {}),
        "alg4_cyclic_isd": (paper_alg4(64), "wavefront", {}),
        "skew_recurrence_64x16_auto": (
            _skew_recurrence_program(64, 16), "wavefront", {},
        ),
        "skew_recurrence_64x16_chunk": (
            _skew_recurrence_program(64, 16),
            "wavefront",
            {"scc_policy": "chunk"},
        ),
        "wide_serialized_8x128_auto": (
            _wide_serialized_recurrence(8, 128), "wavefront", {},
        ),
        "wide_serialized_8x128_chunk": (
            _wide_serialized_recurrence(8, 128),
            "wavefront",
            {"scc_policy": "chunk"},
        ),
        # the xla_policy_backend_aware bench program under BOTH backends:
        # the per-backend strategy divergence (wavefront skews, xla chunks)
        # is exactly what this artifact makes diffable across PRs
        "backend_aware_40x96_wavefront": (
            _wide_serialized_recurrence(40, 96), "wavefront", {},
        ),
        "backend_aware_40x96_xla": (
            _wide_serialized_recurrence(40, 96), "xla", {},
        ),
        # the spmd_wide_wavefront bench pair: the same wide recurrence
        # chunks on single-device xla but skews on the 8-device mesh, and
        # the narrow blocked recurrence keeps chunking even on the mesh
        # (sharding loses) — both sides of the collective-aware auction,
        # diffable across PRs (entry 4 carries an explicit padded store:
        # its (0,-32) reads escape the default pad)
        "spmd_wide_40x96_xla": (
            _wide_serialized_recurrence(40, 96), "xla", {},
        ),
        "spmd_wide_40x96_spmd": (
            _wide_serialized_recurrence(40, 96), "xla_spmd", {},
        ),
        "spmd_narrow_32x32_spmd": (
            _narrow_blocked_recurrence(32),
            "xla_spmd",
            {},
            _narrow_blocked_recurrence(32).initial_store(pad=33),
        ),
    }
    out: Dict[str, dict] = {}
    for name, spec in programs.items():
        prog, backend, kwargs = spec[0], spec[1], spec[2]
        store = spec[3] if len(spec) > 3 else None
        exe = plan(prog, method="isd").compile(backend, **kwargs)
        summary = exe.report().summary()
        summary["strategy_profile"] = obs_profile.profile_executable(
            exe, program=name, store=store
        )
        out[name] = summary
    return out


# the auto/forced pairs of collect_reports() the inversion gate compares:
# same program, same backend, one plan cost-model-chosen and one forced
PROFILE_PAIRS = (
    ("wide_serialized_8x128_auto", "wide_serialized_8x128_chunk"),
    ("skew_recurrence_64x16_auto", "skew_recurrence_64x16_chunk"),
)
# the gate is deliberately LOOSE: it only speaks when the measurement is
# decisive — the losing strategy must be beaten by >1.5x measured wall time
# before a contrary prediction counts as an inversion (one-shot timings on
# a shared runner jitter far more than the cost model's margins)
INVERSION_MARGIN = 1.5


def check_strategy_inversions(reports: Dict[str, dict]) -> int:
    """Predicted-vs-measured sanity gate over the auto/forced pairs.

    An *inversion* is the cost model predicting strategy A cheaper than B
    while the measured wall times say B beats A by more than
    ``INVERSION_MARGIN`` — the model getting a clearly-measured ordering
    backwards.  Returns the number of inversions (0 = pass).
    """

    failures = 0
    for auto_name, forced_name in PROFILE_PAIRS:
        a_rows = (reports.get(auto_name) or {}).get("strategy_profile") or []
        f_rows = (reports.get(forced_name) or {}).get("strategy_profile") or []
        if not a_rows or not f_rows:
            print(
                f"INVERSION-GATE {auto_name} vs {forced_name}: profile rows "
                "missing",
                file=sys.stderr,
            )
            failures += 1
            continue
        a, f = a_rows[0], f_rows[0]
        a_strat, f_strat = a["strategy"], f["strategy"]
        predicted = a.get("predicted") or {}
        if a_strat == f_strat:
            print(
                f"INVERSION-GATE {auto_name} vs {forced_name}: both resolved "
                f"to {a_strat!r} — nothing to compare, OK",
                file=sys.stderr,
            )
            continue
        if a_strat not in predicted or f_strat not in predicted:
            print(
                f"INVERSION-GATE {auto_name} vs {forced_name}: scoreboard "
                f"lacks {a_strat!r}/{f_strat!r} — skipped",
                file=sys.stderr,
            )
            continue
        a_us, f_us = float(a["measured_us"]), float(f["measured_us"])
        verdict = "OK"
        if a_us > INVERSION_MARGIN * f_us and predicted[a_strat] <= predicted[f_strat]:
            # forced strategy measured clearly faster, model preferred auto
            verdict = "INVERTED"
        if f_us > INVERSION_MARGIN * a_us and predicted[f_strat] <= predicted[a_strat]:
            verdict = "INVERTED"
        print(
            f"INVERSION-GATE {auto_name}({a_strat}) vs "
            f"{forced_name}({f_strat}): predicted "
            f"{predicted[a_strat]:.0f} vs {predicted[f_strat]:.0f}, "
            f"measured {a_us:.0f}us vs {f_us:.0f}us "
            f"(margin {INVERSION_MARGIN:.1f}x) {verdict}",
            file=sys.stderr,
        )
        if verdict != "OK":
            failures += 1
    return failures


def main(argv: List[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="also write {name: {us_per_call, derived, ratio?}} to PATH",
    )
    ap.add_argument(
        "--reports",
        metavar="PATH",
        default=None,
        help="write ParallelizationReport.summary() JSON for the benchmark "
        "programs (strategy selection / SCC partition drift artifact)",
    )
    ap.add_argument(
        "--baseline",
        metavar="PATH",
        default=str(DEFAULT_BASELINE),
        help="committed baseline record (default: benchmarks/BASELINE.json)",
    )
    ap.add_argument(
        "--check-baseline",
        action="store_true",
        help=f"fail (exit 1) if any of {', '.join(KEY_BENCHES)} is more than "
        f"{REGRESSION_TOLERANCE:.0%} of its baseline us_per_call",
    )
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="write this run's record to --baseline (the escape hatch after "
        "an intentional perf change; commit the refreshed file)",
    )
    ap.add_argument(
        "--serve",
        metavar="PATH",
        default=None,
        help="write the PlanService.stats() snapshot left by the "
        "serve_sustained_traffic bench (per-tenant cache traffic, "
        "trace/bucket counters, latency percentiles) to PATH — the "
        "SERVE_sync CI artifact",
    )
    ap.add_argument(
        "--obs",
        metavar="PATH",
        default=None,
        help="write the unified metrics snapshot plus a traced "
        "plan->compile->run cycle (Chrome-trace events) to PATH — the "
        "observability CI artifact riding next to SYNC_REPORTS",
    )
    ap.add_argument(
        "--calibrate",
        metavar="PATH",
        default=None,
        help="warm the per-host cost profile (repro.calibrate) before the "
        "timed benches, write it to PATH (the CALIB_sync CI artifact), and "
        "run the strategy-inversion gate against the CALIBRATED cost model "
        "(a re-warm after the benches must reuse the persisted file with "
        "zero re-measurement — asserted).  The timed benches and the "
        "SYNC_REPORTS/OBS artifacts still run on the hand-set defaults so "
        "their numbers stay machine-diffable",
    )
    args = ap.parse_args(argv)

    calib_payload = None
    if args.calibrate:
        import repro.calibrate as calibrate
        from repro.obs import metrics as obs_metrics

        meas = obs_metrics.counter("calibrate.measurements")
        before = meas.value
        prof = calibrate.warm()
        calib_payload = {
            "profile": prof.as_dict(),
            "source": prof.source,
            "path": str(calibrate.profile_path()),
            "measurements_cold": meas.value - before,
        }
        print(
            f"calibrate: {prof.source} profile generation "
            f"{prof.generation} ({meas.value - before} measurements)",
            file=sys.stderr,
        )
        # the timed benches run on the hand-set defaults (deterministic,
        # machine-diffable artifacts); the calibrated model returns for the
        # inversion gate below
        calibrate.reset()

    print("name,us_per_call,derived")
    for bench in BENCHES:
        bench()
    record = {
        str(r["name"]): {
            k: r[k] for k in ("us_per_call", "derived", "ratio") if k in r
        }
        for r in ROWS
    }
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(record, indent=2))
        print(f"wrote {len(record)} benches to {args.json}", file=sys.stderr)
    if args.serve:
        pathlib.Path(args.serve).write_text(json.dumps(SERVE_STATS, indent=2))
        print(
            f"wrote serve stats snapshot ({len(SERVE_STATS)} keys) to "
            f"{args.serve}",
            file=sys.stderr,
        )
    reports = None
    if args.reports:
        reports = collect_reports()
        pathlib.Path(args.reports).write_text(json.dumps(reports, indent=2))
        print(
            f"wrote {len(reports)} parallelization reports to {args.reports}",
            file=sys.stderr,
        )
    if args.obs:
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        from repro.core import paper_alg6, plan

        # the traced cycle runs AFTER the timed benches, so enabling the
        # tracer here cannot perturb any gated number; the metrics snapshot
        # covers the whole bench process (cache traffic, backend run
        # counts, speculation counters)
        obs_trace.clear()
        with obs_trace.tracing():
            plan(paper_alg6(64), method="isd").compile("wavefront").run()
        payload = {
            "metrics": obs_metrics.snapshot(),
            "trace": obs_trace.to_chrome_trace(),
        }
        pathlib.Path(args.obs).write_text(json.dumps(payload, indent=2))
        print(
            f"wrote obs artifact (metrics snapshot + "
            f"{len(payload['trace']['traceEvents'])} trace events) to "
            f"{args.obs}",
            file=sys.stderr,
        )
    calibrated_reports = None
    if args.calibrate:
        import repro.calibrate as calibrate
        from repro.obs import metrics as obs_metrics
        from repro.core import clear_analysis_cache

        # "restart" reuse: the re-warm must load the file persisted above
        # with ZERO re-measurement (the acceptance criterion —
        # calibrate.measurements stays flat)
        meas = obs_metrics.counter("calibrate.measurements")
        before = meas.value
        prof = calibrate.warm()
        rewarm_measurements = meas.value - before
        assert rewarm_measurements == 0, (
            f"re-warm re-measured ({rewarm_measurements} samples) instead "
            "of reusing the persisted profile"
        )
        assert prof.source in ("measured", "persisted")
        calib_payload["measurements_rewarm"] = rewarm_measurements
        calib_payload["rewarm_source"] = prof.source
        # re-run the auction under the measured units: fresh plans (the
        # analysis memo deliberately ignores calibration), then the
        # predicted-vs-measured inversion gate against the calibrated model
        clear_analysis_cache()
        calibrated_reports = collect_reports()
        calib_payload["calibrated_strategies"] = {
            name: [
                (r["strategy"], r.get("predicted"))
                for r in (rep.get("strategy_profile") or [])
            ]
            for name, rep in calibrated_reports.items()
        }
        calibrate.reset()
        clear_analysis_cache()
        pathlib.Path(args.calibrate).write_text(
            json.dumps(calib_payload, indent=2)
        )
        print(
            f"wrote calibration artifact (generation "
            f"{calib_payload['profile']['generation']}, rewarm "
            f"measurements {rewarm_measurements}) to {args.calibrate}",
            file=sys.stderr,
        )
    if args.update_baseline:
        pathlib.Path(args.baseline).write_text(json.dumps(record, indent=2))
        print(f"updated baseline {args.baseline}", file=sys.stderr)
    if args.check_baseline:
        failures = check_baseline(record, pathlib.Path(args.baseline))
        # the inversion gate judges the CALIBRATED model when a profile was
        # warmed this run — measured units are the model actually serving
        # auctions on this host — and the hand-set defaults otherwise
        if calibrated_reports is not None:
            failures += check_strategy_inversions(calibrated_reports)
        else:
            if reports is None:
                reports = collect_reports()
            failures += check_strategy_inversions(reports)
        if failures:
            sys.exit(1)


if __name__ == "__main__":
    main()
