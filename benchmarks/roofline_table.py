"""Render the §Roofline table from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod16x16]

Columns per (arch × shape): the three roofline terms (HLO-derived and
analytic), dominant term, MODEL_FLOPS/HLO_FLOPs ratio, roofline-MFU, and
memory-fit status of the deployment compile.
"""

from __future__ import annotations

import argparse
import json
import pathlib

HBM_PER_CHIP = 16e9  # TPU v5e-class


def load_records(out_dir: pathlib.Path, mesh: str):
    recs = []
    for f in sorted(out_dir.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            recs.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return recs


def fit_status(r: dict) -> str:
    mem = r.get("memory_deploy") or r.get("memory", {})
    if "error" in mem:
        return "n/a"
    total = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
    return f"{total/1e9:.1f}GB {'OK' if total <= HBM_PER_CHIP else 'OVER'}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument(
        "--dir",
        default=str(pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"),
    )
    args = ap.parse_args()
    recs = load_records(pathlib.Path(args.dir), args.mesh)

    hdr = (
        "| arch | shape | HLO c/m/coll (s) | analytic c/m/coll (s) | dominant "
        "| useful/HLO | MFU(roofline) | mem/chip |"
    )
    print(hdr)
    print("|" + "---|" * 8)
    for r in recs:
        if "skipped" in r:
            print(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                f"skipped: sub-quadratic gate |"
            )
            continue
        t = r["roofline"]
        a = r["roofline_analytic"]
        dominant = a["dominant"]
        print(
            f"| {r['arch']} | {r['shape']} "
            f"| {t['compute_s']:.3f}/{t['memory_s']:.3f}/{t['collective_s']:.3f} "
            f"| {a['compute_s']:.3f}/{a['memory_s']:.3f}/{a['collective_s']:.3f} "
            f"| {dominant} "
            f"| {t['useful_flops_fraction']:.2f} "
            f"| {a['mfu']:.3f} "
            f"| {fit_status(r)} |"
        )


if __name__ == "__main__":
    main()
